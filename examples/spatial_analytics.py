"""Spatial analytics over a Spatial Parquet data lake (the paper's workload).

Writes all four dataset analogs as a small data lake, then answers analytical
queries using projection + range-filter pushdown + the columnar fast path:

  1. count points per region (index-pruned range scans),
  2. average trajectory length in a city bbox (needs only x/y + levels),
  3. densest hotspot among sampled query cells,
  4. storage report per format (the Table 2 story, live).

    PYTHONPATH=src python examples/spatial_analytics.py
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from repro.data.synthetic import PORTO_BBOX, US_BBOX, ebird_like, porto_taxi_like
from repro.core.pages import best_codec


def main():
    lake = tempfile.mkdtemp(prefix="lake_")

    pt = porto_taxi_like(n_traj=4000, seed=0)
    eb = ebird_like(n_points=200_000, seed=1)
    paths = {}
    for name, cols in (("porto", pt), ("ebird", eb)):
        p = os.path.join(lake, f"{name}.spqf")
        write_file(p, columns=cols, sort="hilbert", codec=best_codec(), page_values=8192)
        paths[name] = p
        print(f"[lake] {name}: {cols.n_values} pts -> {os.path.getsize(p)/1e6:.2f} MB")

    # --- 1. regional counts with page pruning
    with SpatialParquetReader(paths["ebird"]) as r:
        quads = {
            "NW": (US_BBOX[0], (US_BBOX[1]+US_BBOX[3])/2, (US_BBOX[0]+US_BBOX[2])/2, US_BBOX[3]),
            "SE": ((US_BBOX[0]+US_BBOX[2])/2, US_BBOX[1], US_BBOX[2], (US_BBOX[1]+US_BBOX[3])/2),
        }
        for qname, q in quads.items():
            t0 = time.time()
            cols, _, st = r.read_columnar(bbox=q, refine=True)
            n = cols.n_records if cols else 0
            print(f"[q1] ebird {qname}: {n} observations "
                  f"(pages {st.pages_read}/{st.pages_total}, {1e3*(time.time()-t0):.0f}ms)")

    # --- 2. average trajectory length in central Porto
    with SpatialParquetReader(paths["porto"]) as r:
        cx = (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2
        cy = (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2
        q = (cx - 0.03, cy - 0.03, cx + 0.03, cy + 0.03)
        cols, _, st = r.read_columnar(bbox=q, refine=True)
        if cols is not None and cols.n_records:
            starts = cols.record_value_starts()
            counts = np.diff(np.append(starts, cols.n_values))
            # haversine-ish path length (flat-earth at city scale)
            dx = np.diff(cols.x) * 111e3 * np.cos(np.radians(cy))
            dy = np.diff(cols.y) * 111e3
            seg = np.sqrt(dx**2 + dy**2)
            seg[np.cumsum(counts)[:-1] - 1] = 0  # cut segments across records
            print(f"[q2] central Porto: {cols.n_records} trajectories, "
                  f"mean {counts.mean():.1f} pts, mean path {seg.sum()/cols.n_records:.0f} m "
                  f"(pages {st.pages_read}/{st.pages_total})")

    # --- 3. densest cell among sampled candidates
    with SpatialParquetReader(paths["ebird"]) as r:
        rng = np.random.default_rng(0)
        best = (None, -1)
        scanned = []
        for _ in range(12):
            x0 = rng.uniform(US_BBOX[0], US_BBOX[2] - 1)
            y0 = rng.uniform(US_BBOX[1], US_BBOX[3] - 1)
            q = (x0, y0, x0 + 1.0, y0 + 1.0)
            cols, _, st = r.read_columnar(bbox=q, refine=True)
            n = cols.n_records if cols else 0
            scanned.append(st.pages_read)
            if n > best[1]:
                best = (q, n)
        print(f"[q3] densest of 12 sampled 1-degree cells: {best[1]} obs at "
              f"({best[0][0]:.2f},{best[0][1]:.2f}); mean pages/query "
              f"{np.mean(scanned):.1f} of {st.pages_total}")

    print(f"[done] lake at {lake}")


if __name__ == "__main__":
    main()

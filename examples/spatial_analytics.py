"""Spatial analytics over a sharded Spatial Parquet data lake.

Writes the dataset analogs as *sharded datasets* (SFC-partitioned shards +
JSON manifest, ``repro.dataset``), then answers analytical queries with the
two-level index — shard MBR pruning first, per-page [min,max] pruning inside
each surviving shard — and the async fan-out scanner:

  1. count points per region (shard + page pruned range scans),
  2. average trajectory length in a city bbox (needs only x/y + levels),
  3. densest hotspot among sampled query cells,
  4. bytes touched per query vs the whole lake (the Figure 11 story, live).

    PYTHONPATH=src python examples/spatial_analytics.py
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.pages import best_codec
from repro.data.synthetic import PORTO_BBOX, US_BBOX, ebird_like, porto_taxi_like
from repro.dataset import SpatialDatasetScanner, write_dataset


def main():
    lake = tempfile.mkdtemp(prefix="lake_")

    pt = porto_taxi_like(n_traj=4000, seed=0)
    eb = ebird_like(n_points=200_000, seed=1)
    scanners = {}
    for name, cols, shards in (("porto", pt, 4), ("ebird", eb, 8)):
        root = os.path.join(lake, name)
        m = write_dataset(root, columns=cols, n_shards=shards, sort="hilbert",
                          codec=best_codec(), page_values=8192)
        total_mb = sum(s.file_bytes for s in m.shards) / 1e6
        scanners[name] = SpatialDatasetScanner(root, max_workers=4)
        print(f"[lake] {name}: {cols.n_values} pts -> {m.n_shards} shards, "
              f"{total_mb:.2f} MB")

    # --- 1. regional counts with shard + page pruning
    sc = scanners["ebird"]
    quads = {
        "NW": (US_BBOX[0], (US_BBOX[1]+US_BBOX[3])/2, (US_BBOX[0]+US_BBOX[2])/2, US_BBOX[3]),
        "SE": ((US_BBOX[0]+US_BBOX[2])/2, US_BBOX[1], US_BBOX[2], (US_BBOX[1]+US_BBOX[3])/2),
    }
    for qname, q in quads.items():
        t0 = time.time()
        cols, _, st = sc.scan(bbox=q, refine=True)
        n = cols.n_records if cols else 0
        print(f"[q1] ebird {qname}: {n} observations "
              f"(shards {st.shards_read}/{st.shards_total}, "
              f"pages {st.pages_read}/{st.pages_total}, "
              f"{1e3*(time.time()-t0):.0f}ms)")

    # --- 2. average trajectory length in central Porto
    sc = scanners["porto"]
    cy = (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2
    q = central_porto_box()
    cols, _, st = sc.scan(bbox=q, refine=True)
    if cols is not None and cols.n_records:
        starts = cols.record_value_starts()
        counts = np.diff(np.append(starts, cols.n_values))
        # haversine-ish path length (flat-earth at city scale)
        dx = np.diff(cols.x) * 111e3 * np.cos(np.radians(cy))
        dy = np.diff(cols.y) * 111e3
        seg = np.sqrt(dx**2 + dy**2)
        seg[np.cumsum(counts)[:-1] - 1] = 0  # cut segments across records
        print(f"[q2] central Porto: {cols.n_records} trajectories, "
              f"mean {counts.mean():.1f} pts, mean path {seg.sum()/cols.n_records:.0f} m "
              f"(shards {st.shards_read}/{st.shards_total}, "
              f"pages {st.pages_read}/{st.pages_total})")

    # --- 3. densest cell among sampled candidates
    sc = scanners["ebird"]
    rng = np.random.default_rng(0)
    best = (None, -1)
    bytes_frac = []
    for _ in range(12):
        x0 = rng.uniform(US_BBOX[0], US_BBOX[2] - 1)
        y0 = rng.uniform(US_BBOX[1], US_BBOX[3] - 1)
        q = (x0, y0, x0 + 1.0, y0 + 1.0)
        cols, _, st = sc.scan(bbox=q, refine=True)
        n = cols.n_records if cols else 0
        bytes_frac.append(st.bytes_read / st.bytes_total)
        if n > best[1]:
            best = (q, n)
    print(f"[q3] densest of 12 sampled 1-degree cells: {best[1]} obs at "
          f"({best[0][0]:.2f},{best[0][1]:.2f}); mean bytes touched/query "
          f"{100*np.mean(bytes_frac):.1f}% of the lake")

    # --- 4. pruning report: bytes touched vs the whole lake (stats already
    # carry the lake-wide denominator; no full scan needed)
    for name, q in (("porto", central_porto_box()),
                    ("ebird", (US_BBOX[0], US_BBOX[1],
                               US_BBOX[0]+4, US_BBOX[1]+4))):
        _, _, st = scanners[name].scan(bbox=q)
        print(f"[q4] {name}: bbox query reads {st.bytes_read/1e3:.0f} kB of "
              f"{st.bytes_total/1e3:.0f} kB "
              f"({100*st.bytes_read/max(st.bytes_total,1):.1f}%, "
              f"shards {st.shards_read}/{st.shards_total})")

    print(f"[done] lake at {lake}")


def central_porto_box():
    cx = (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2
    cy = (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2
    return (cx - 0.03, cy - 0.03, cx + 0.03, cy + 0.03)


if __name__ == "__main__":
    main()

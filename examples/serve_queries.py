"""Serving concurrent bbox queries with shared row-group decodes.

Builds a small sharded Spatial Parquet lake, stands up a
:class:`~repro.serve.query_scheduler.SpatialQueryServer`, and submits a
burst of overlapping bbox queries. The server groups the burst into one
admission wave, decodes each surviving row group **once**, and answers every
query out of the shared decode — then a second identical burst is served
entirely from the decoded-row-group cache (compare-only work, no decode).
Each query's results and ReadStats are exactly what its solo
``scanner.scan(bbox, refine=True)`` would have returned.

    PYTHONPATH=src python examples/serve_queries.py [--device jax]
"""

import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.dataset import SpatialDatasetScanner, write_dataset
from repro.serve.query_scheduler import SpatialQueryServer


def grid_boxes(n=4):
    x0, y0, x1, y1 = PORTO_BBOX
    xs = np.linspace(x0, x1, n + 1)
    ys = np.linspace(y0, y1, n + 1)
    return [(xs[i], ys[j], xs[i + 1], ys[j + 1])
            for i in range(n) for j in range(n)]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--device", default="cpu", choices=("cpu", "jax"))
    args = ap.parse_args()

    root = os.path.join(tempfile.mkdtemp(prefix="serve_lake_"), "pt")
    cols = porto_taxi_like(n_traj=4000, seed=0)
    write_dataset(root, columns=cols, n_shards=4, sort="hilbert",
                  page_values=8192)
    sc = SpatialDatasetScanner(root)

    boxes = grid_boxes(4) + [PORTO_BBOX]
    with SpatialQueryServer(sc, device=args.device, cache_rgs=64) as srv:
        t0 = time.perf_counter()
        queries = [srv.submit(b) for b in boxes]
        srv.run()
        cold = time.perf_counter() - t0
        for q in queries[:4]:
            n = q.geo.n_records if q.geo is not None else 0
            print(f"  query {q.qid}: {n:6d} trajectories, "
                  f"{q.stats.bytes_read:>9d} bytes attributed, "
                  f"{q.latency_s * 1e3:7.2f} ms")
        m = srv.metrics()
        print(f"cold burst: {len(boxes)} queries in {cold * 1e3:.1f} ms — "
              f"{m['rg_decodes']} row-group decodes for "
              f"{m['rg_touches']} touches "
              f"(shared-decode ratio {m['shared_decode_ratio']:.1f})")

        t0 = time.perf_counter()
        for b in boxes:
            srv.submit(b)
        srv.run()
        warm = time.perf_counter() - t0
        m = srv.metrics()
        print(f"warm burst: {warm * 1e3:.1f} ms — cache hits {m['cache_hits']}, "
              f"decodes still {m['rg_decodes']} (served from cache)")

    # the same queries, unshared, for comparison
    t0 = time.perf_counter()
    for b in boxes:
        sc.scan(bbox=b, refine=True, device=args.device, parallel=False)
    solo = time.perf_counter() - t0
    print(f"sequential solo scans: {solo * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
